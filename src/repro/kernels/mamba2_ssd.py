"""Mamba2 SSD chunked-scan Pallas kernel.

One (batch, head) per grid row, chunks sequential along the second grid axis,
(N, P) recurrent state in persistent VMEM scratch. Math matches
models.ssm.ssd_chunked (the ref oracle): intra-chunk lower-triangular decay
"attention" + inter-chunk decayed state contribution + state update.

Block shapes (Q=128, N=64, P=64): l_mat (Q, Q) is 64 KB; matmuls are
(Q x N)(N x Q) and (Q x Q)(Q x P) MXU tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1)
    a = a_ref[0, 0]                           # () decay rate (negative)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)
    state = state_ref[...]                    # (N, P)

    da = dt[:, 0] * a                         # (Q,)
    cum = jnp.cumsum(da)                      # inclusive
    xdt = x * dt
    rel = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(ii >= jj, jnp.exp(rel), 0.0)
    scores = c @ b.T                          # (Q, Q)
    y = (scores * l_mat) @ xdt
    y = y + (c * jnp.exp(cum)[:, None]) @ state
    y_ref[0] = y.astype(y_ref.dtype)

    to_end = jnp.exp(cum[-1] - cum)           # (Q,)
    s_c = (b * to_end[:, None]).T @ xdt       # (N, P)
    state_ref[...] = state * jnp.exp(cum[-1]) + s_c


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
        *, chunk: int = 128, interpret: bool = False) -> jax.Array:
    """x: (BH, S, P); dt: (BH, S); a: (BH,); b, c: (BH, S, N) — flattened
    over (batch, head) with B/C groups pre-broadcast. Returns y (BH, S, P)."""
    bh, s, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    dt3 = dt[..., None]
    a2 = a.reshape(bh, 1)

    y = pl.pallas_call(
        functools.partial(_kernel, q=chunk),
        grid=(bh, s // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt3, a2, b, c)
    return y
