"""Pallas paged attention: decode and chunked-prefill variants over a
block-paged KV cache whose blocks live at non-contiguous pool slots.

``paged_attention`` (decode): grid (B, MB), one query token per sequence. The
per-sequence block table is a *scalar-prefetch* operand, so the BlockSpec
index map DMAs exactly the K/V blocks the sequence owns — gathering from the
pool without ever materializing a contiguous (B, T) cache. The MB axis is
sequential per sequence; softmax runs in streaming (flash) form with running
(max, denom, acc) scratch carried across blocks, and blocks past
``context_len`` are skipped entirely (their DMA still targets a valid pool
slot — the shared null block 0 — so the index map stays in bounds).

``paged_prefill_attention`` (mixed chunked-prefill/decode iterations): grid
(T, MB) over a *flat token batch* — several tokens may belong to the same
sequence (a prefill chunk) while others are single decode tokens of other
sequences. A third scalar-prefetch operand, ``slot_ids``, maps each token to
its block-table row; per-token ``context_lens`` (= position + 1) express
intra-chunk causality, because the chunk's own K/V is scattered into the
pool before the kernel runs.

Head/lane tiling note: shapes here are serving-sized (Hq x D panels); on real
TPUs Hq*G and D should be padded to the (8, 128) tile by the ops.py wrapper.
Tests validate via interpret mode against ``ref.paged_attention_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_body(ctx, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                bs: int, softcap: float, groups: int):
    """Shared streaming-softmax block step for both paged kernels: the grid
    row (a batch slot for decode, a flat token for chunked prefill) has
    already resolved its K/V block and ``ctx`` valid keys."""
    j = pl.program_id(1)
    mb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j * bs < ctx)
    def _block():
        q = q_ref[0].astype(jnp.float32)                 # (Hq, D)
        k = k_ref[0].astype(jnp.float32)                 # (BS, Hkv, D)
        v = v_ref[0].astype(jnp.float32)
        hq, d = q.shape
        hkv = k.shape[1]
        qg = (q * (1.0 / math.sqrt(d))).reshape(hkv, groups, d)
        # (Hkv, G, BS) logits via per-kv-head batched contraction
        logits = jax.lax.dot_general(
            qg, jnp.moveaxis(k, 0, 1),                   # (Hkv, BS, D)
            dimension_numbers=(((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        if softcap and softcap > 0.0:
            logits = softcap * jnp.tanh(logits / softcap)
        k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
        logits = jnp.where(k_pos < ctx, logits, NEG_INF)
        logits = logits.reshape(hq, bs)

        m_prev, l_prev = m_ref[...], l_ref[...]          # (Hq, 1)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)                      # (Hq, BS)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.reshape(hkv, groups, bs), jnp.moveaxis(v, 0, 1),
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)          # (Hkv, G, D)
        acc_ref[...] = acc_ref[...] * alpha + pv.reshape(hq, d)

    @pl.when(j == mb - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def _kernel(block_tables_ref, context_lens_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, bs: int, softcap: float, groups: int):
    ctx = context_lens_ref[pl.program_id(0)]
    _flash_body(ctx, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                bs=bs, softcap=softcap, groups=groups)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, context_lens: jax.Array, *,
                    softcap: float = 0.0, interpret: bool = False) -> jax.Array:
    """Paged decode attention: one query token per batch slot.

    Contract (see docs/kernels.md for the full operand walkthrough):

    * ``q``: (B, Hq, D) — the decode batch's current tokens.
    * ``k_pool`` / ``v_pool``: (NB, BS, Hkv, D) — global block pools; a
      sequence's K/V lives at the (non-contiguous) blocks its table names.
      Hq must be a multiple of Hkv (grouped-query heads).
    * ``block_tables``: (B, MB) int32 — scalar-prefetch operand; entry
      ``[i, j]`` is the pool slot of sequence ``i``'s ``j``-th block.
      Unused entries must point at a valid pool slot (the shared null
      block 0) so every grid step's DMA stays in bounds.
    * ``context_lens``: (B,) int32 — keys visible to each query; blocks at
      or past the length are skipped (their values never enter the
      softmax), so stale data in reused blocks is harmless.
    * ``softcap`` > 0 applies ``softcap * tanh(logits / softcap)``.

    Grid is (B, MB), MB innermost and sequential per sequence: streaming
    (flash) softmax over blocks with float32 running (max, denom, acc)
    scratch. Returns (B, Hq, D) in ``q``'s dtype. Prefer calling through
    ``ops.paged_attention_forward`` — it owns the ref/Pallas/interpret
    dispatch and the sliding-window oracle fallback."""
    b, hq, d = q.shape
    _, bs, hkv, _ = k_pool.shape
    mb = block_tables.shape[1]
    groups = hq // hkv
    assert groups * hkv == hq, (hq, hkv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, mb),
        in_specs=[
            pl.BlockSpec((1, hq, d), lambda i, j, bt, cl: (i, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d), lambda i, j, bt, cl: (bt[i, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d), lambda i, j, bt, cl: (bt[i, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, d), lambda i, j, bt, cl: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs, softcap=softcap, groups=groups),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), context_lens.astype(jnp.int32),
      q, k_pool, v_pool)


def _prefill_kernel(slot_ids_ref, block_tables_ref, context_lens_ref,
                    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                    bs: int, softcap: float, groups: int):
    """Grid's first axis is a flat token index instead of a batch slot; the
    block table row was resolved through ``slot_ids`` by the index maps, so
    the body only needs the per-token context length."""
    ctx = context_lens_ref[pl.program_id(0)]
    _flash_body(ctx, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                bs=bs, softcap=softcap, groups=groups)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_prefill_attention(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_tables: jax.Array,
                            slot_ids: jax.Array, context_lens: jax.Array, *,
                            softcap: float = 0.0,
                            interpret: bool = False) -> jax.Array:
    """Flat-token paged attention for mixed prefill/decode iterations and
    speculative verify runs.

    Contract (see docs/kernels.md):

    * ``q``: (T, Hq, D) — ONE flat token batch: decode tokens, prompt
      chunks, draft-warmup feeds, and k+1-token verify runs all mix here;
      consecutive tokens of one run belong to the same sequence.
    * ``slot_ids``: (T,) int32 — third scalar-prefetch operand mapping
      each token to its block-table ROW. Pad tokens must point at an
      appended row of null blocks, never at a live sequence.
    * ``block_tables``: (B + null_rows, MB) int32 — as in
      ``paged_attention``, plus the pad rows.
    * ``context_lens``: (T,) int32 — per TOKEN, ``position + 1``: the
      token's own causal horizon. Intra-chunk causality works because the
      caller scatters the whole chunk's K/V into the pool *before* this
      kernel runs; token ``i`` of a chunk then sees exactly its prefix.
    * ``softcap`` as in ``paged_attention``.

    Grid is (T, MB); the block-table row is resolved through
    ``slot_ids`` inside the BlockSpec index maps, so the body is the same
    streaming-softmax step as the decode kernel (``_flash_body``).
    Returns (T, Hq, D). Prefer ``ops.paged_prefill_attention_forward``
    for dispatch."""
    t, hq, d = q.shape
    _, bs, hkv, _ = k_pool.shape
    mb = block_tables.shape[1]
    groups = hq // hkv
    assert groups * hkv == hq, (hq, hkv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(t, mb),
        in_specs=[
            pl.BlockSpec((1, hq, d), lambda i, j, sid, bt, cl: (i, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d),
                         lambda i, j, sid, bt, cl: (bt[sid[i], j], 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d),
                         lambda i, j, sid, bt, cl: (bt[sid[i], j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, d), lambda i, j, sid, bt, cl: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_prefill_kernel, bs=bs, softcap=softcap,
                          groups=groups),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, hq, d), q.dtype),
        interpret=interpret,
    )(slot_ids.astype(jnp.int32), block_tables.astype(jnp.int32),
      context_lens.astype(jnp.int32), q, k_pool, v_pool)
