"""RWKV6 WKV chunked-recurrence Pallas kernel.

One (batch, head) per grid row; chunks advance along the second (sequential)
grid axis with the (N, N) recurrent state living in a VMEM scratch buffer that
persists across chunk steps — the standard TPU sequential-grid carry pattern.
Math identical to models.rwkv.wkv_chunked (the ref oracle): lower-triangular
intra-chunk decay matrix from cumulative log-decays, bonus ``u`` on the
diagonal, state decay/update per chunk.

Block shapes (Q=64, N=64): the (Q, Q, N) pairwise-decay tensor is 1 MB fp32 —
comfortably VMEM-resident; all matmuls are 64x64x64 MXU tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_ref, *, q: int, n: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # (Q, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, N) bonus row
    state = state_ref[...]                    # (N, N)

    logw = jnp.log(jnp.maximum(w, 1e-12))
    cum = jnp.cumsum(logw, axis=0)            # (Q, N) inclusive
    cum_prev = cum - logw
    rel = cum_prev[:, None, :] - cum[None, :, :]            # (Qi, Qj, N)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = (ii > jj)[:, :, None]
    decay_ij = jnp.where(tri, jnp.exp(rel), 0.0)
    att = jnp.einsum("in,ijn,jn->ij", r, decay_ij, k)
    diag = jnp.sum(r * u * k, axis=1)                        # (Q,)
    y = att @ v + diag[:, None] * v
    y = y + (r * jnp.exp(cum_prev)) @ state
    y_ref[0] = y.astype(y_ref.dtype)

    to_end = jnp.exp(cum[-1:] - cum)                         # (Q, N)
    s_c = (k * to_end).T @ v                                 # (N, N)
    state_ref[...] = state * jnp.exp(cum[-1])[:, None] + s_c


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
         *, chunk: int = 64, interpret: bool = False) -> jax.Array:
    """r/k/v/w: (BH, S, N) flattened over batch*heads; u: (BH, N) bonus.

    Returns y (BH, S, N). S % chunk == 0 (ops.py pads).
    """
    bh, s, n = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    u2 = u[:, None, :]  # (BH, 1, N)

    y = pl.pallas_call(
        functools.partial(_kernel, q=chunk, n=n),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, n), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, n), r.dtype),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u2)
    return y
