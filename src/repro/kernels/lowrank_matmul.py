"""Fused naive low-rank forward: y = (x @ v) @ u^T in one pallas_call.

The training-path analogue of gar_matmul (factors in paper (U, V) form,
z (T, r) stays in VMEM). Supports the nested rank *mask* (paper §3.3): a
traced ``rank`` scalar zeroes z columns >= rank inside the kernel, so the
stochastic-budget training step needs no extra memory traffic for masking.

Grid (T/bt, r/br): y is accumulated over the r grid axis (sequential TPU
grid, revisit-accumulate). Masked r-blocks still run (static shapes) — this
is the paper's documented ~2x training overhead; the *deploy* path uses
gar_matmul with statically sliced ranks instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BT = 256
DEFAULT_BR = 256


def _kernel(rank_ref, x_ref, v_ref, u_ref, y_ref, *, br: int):
    j = pl.program_id(1)
    x = x_ref[...]
    v = v_ref[...]
    z = jnp.dot(x, v, preferred_element_type=jnp.float32)
    col = j * br + jax.lax.broadcasted_iota(jnp.int32, (1, br), 1)
    mask = (col < rank_ref[0]).astype(z.dtype)
    z = z * mask
    u = u_ref[...]
    partial = jnp.dot(z.astype(x.dtype), u.T, preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        y_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("bt", "br", "interpret"))
def lowrank_matmul(x: jax.Array, v: jax.Array, u: jax.Array,
                   rank: jax.Array | int | None = None, *,
                   bt: int = DEFAULT_BT, br: int = DEFAULT_BR,
                   interpret: bool = False) -> jax.Array:
    """y = (x @ v) * mask(rank) @ u^T.  x: (T, n); v: (n, r); u: (m, r)."""
    t, n = x.shape
    r = v.shape[1]
    m = u.shape[0]
    assert t % bt == 0 and r % br == 0, (t, bt, r, br)
    if rank is None:
        rank = r
    rank_arr = jnp.asarray(rank, jnp.int32).reshape(1)

    y = pl.pallas_call(
        functools.partial(_kernel, br=br),
        grid=(t // bt, r // br),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY) if False else pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bt, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, br), lambda i, j: (0, j)),
            pl.BlockSpec((m, br), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bt, m), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m), jnp.float32),
        interpret=interpret,
    )(rank_arr, x, v, u)
    return y.astype(x.dtype)
