"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gar_matmul_ref(x, v_tilde, u_hat):
    """(z, tail) for z = x@v_tilde, tail = z@u_hat^T."""
    z = x @ v_tilde
    return z, z @ u_hat.T


def lowrank_matmul_ref(x, v, u, rank=None):
    z = x @ v
    if rank is not None:
        mask = (jnp.arange(z.shape[-1]) < rank).astype(z.dtype)
        z = z * mask
    return z @ u.T


def wkv6_ref(r, k, v, w, u):
    """Sequential WKV6 recurrence. r/k/v/w: (BH, S, N); u: (BH, N)."""
    bh, s, n = r.shape

    def step(state, xs):
        r_t, k_t, v_t, w_t = xs  # (BH, N)
        kv = k_t[:, :, None] * v_t[:, None, :]           # (BH, N, N)
        y = jnp.einsum("bn,bnm->bm", r_t, state + u[:, :, None] * kv)
        state = state * w_t[:, :, None] + kv
        return state, y

    init = jnp.zeros((bh, n, n), jnp.float32)
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)


def paged_attention_ref(q, k_pool, v_pool, block_tables, context_lens, *,
                        softcap=0.0, window=None):
    """Decode attention over a block-paged KV cache (gather + plain softmax).

    q: (B, Hq, D) — one query token per sequence, pre-RoPE'd.
    k_pool/v_pool: (NB, BS, Hkv, D) — global block pools.
    block_tables: (B, MB) int32 — per-sequence block ids (0 = null block).
    context_lens: (B,) int32 — valid tokens per sequence (incl. current).

    Numerics deliberately mirror ``models.attention.chunked_attend`` (q
    pre-scaled, fp32 logits, -1e30 mask) so the paged engine stays
    token-identical to the contiguous decode path.
    """
    import math
    b, hq, d = q.shape
    nb, bs, hkv, _ = k_pool.shape
    mb = block_tables.shape[1]
    g = hq // hkv
    k = jnp.take(k_pool, block_tables, axis=0).reshape(b, mb * bs, hkv, d)
    v = jnp.take(v_pool, block_tables, axis=0).reshape(b, mb * bs, hkv, d)
    qg = (q * (1.0 / math.sqrt(d))).reshape(b, hkv, g, d)
    logits = jnp.einsum("bhgd,bthd->bhgt", qg, k).astype(jnp.float32)
    if softcap and softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    k_pos = jnp.arange(mb * bs, dtype=jnp.int32)[None, :]        # (1, T)
    valid = k_pos < context_lens[:, None]
    if window is not None:
        valid &= k_pos >= (context_lens[:, None] - window)
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgt,bthd->bhgd", probs, v)
    return out.reshape(b, hq, d).astype(q.dtype)


def paged_prefill_attention_ref(q, k_pool, v_pool, block_tables, slot_ids,
                                context_lens, *, softcap=0.0, window=None):
    """Chunked-prefill attention over a block-paged KV cache.

    Generalizes ``paged_attention_ref`` from one-query-per-sequence to a flat
    token batch: query ``t`` belongs to batch slot ``slot_ids[t]`` and attends
    over the first ``context_lens[t]`` keys of that slot's block table (its
    own K/V must already be scattered into the pool, so intra-chunk causality
    is expressed purely through per-token context lengths).

    q: (T, Hq, D) — flat chunk/decode tokens, pre-RoPE'd.
    block_tables: (B, MB) int32 — per-slot block ids (0 = null block).
    slot_ids: (T,) int32 — row of ``block_tables`` for each token (point pad
    tokens at a row of null blocks).
    context_lens: (T,) int32 — ``position + 1`` of each token in its sequence.
    """
    per_token_tables = jnp.take(block_tables, slot_ids, axis=0)   # (T, MB)
    return paged_attention_ref(q, k_pool, v_pool, per_token_tables,
                               context_lens, softcap=softcap, window=window)


def topk_threshold_ref(z, top_k):
    """Per-row top-k cutoff on already-temperature-scaled logits.

    z: (S, V); top_k: (S,) int32 — 0 means no truncation. Returns (S,)
    thresholds: the k-th largest value of each row (rows keep every entry
    ``>= threshold``, so exact ties at the cutoff survive — matching the
    host sampler's ``np.partition`` rule), or -inf where ``top_k == 0``.
    """
    v = z.shape[-1]
    srt = jnp.sort(z, axis=-1)[:, ::-1]                     # descending
    k = jnp.clip(top_k, 1, v) - 1
    thr = jnp.take_along_axis(srt, k[:, None], axis=-1)[:, 0]
    return jnp.where(top_k > 0, thr, -jnp.inf)


def warp_probs_ref(logits, temperature, threshold):
    """Warped categorical per row: temperature scaling then threshold mask.

    logits: (S, V); temperature: (S,) with <= 0 meaning greedy (one-hot
    argmax, the zero-temperature limit); threshold: (S,) top-k cutoff on the
    *scaled* logits (-inf = no truncation). Returns (S, V) normalized
    probabilities — the device mirror of
    ``serving.sampling.SamplerState.probs`` (float32 instead of the host
    oracle's float64).
    """
    v = logits.shape[-1]
    t = jnp.maximum(temperature, 1e-30)[:, None]
    z = logits.astype(jnp.float32) / t
    z = jnp.where(z >= threshold[:, None], z, -jnp.inf)
    z = z - jnp.max(z, axis=-1, keepdims=True)
    p = jnp.exp(z)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    one_hot = (jnp.argmax(logits, axis=-1)[:, None]
               == jnp.arange(v)[None, :]).astype(jnp.float32)
    return jnp.where(temperature[:, None] > 0, p, one_hot)


def sample_cdf_ref(weights, u, block: int = 1024):
    """Inverse-CDF sample per row from non-negative (possibly unnormalized)
    weights with one uniform each — the device mirror of
    ``serving.sampling.sample_from`` (same ``searchsorted(side="right")``
    boundary rule: the token index is the count of CDF entries <= u * total,
    clamped to the last token). weights: (S, V); u: (S,). Returns (S,) int32.

    Two-level CDF: per-block sums locate the crossing block, then one small
    within-block scan resolves the index — a full-vocab ``cumsum`` lowers
    to a serial scan on CPU/TPU and dominated the fused sampler's cost at
    128k vocab. The blocked prefix (carry of block sums + within-block
    cumsum) is exactly the Pallas kernel's streaming structure, so kernel
    and oracle keep token-level parity.
    """
    s, v = weights.shape
    bv = min(block, v)
    pad = (-v) % bv
    w = weights.astype(jnp.float32)
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))     # zero weight: never crossed
    nb = w.shape[1] // bv
    blocks = w.reshape(s, nb, bv)
    bs = jnp.sum(blocks, axis=-1)              # (S, NB) block sums
    cum = jnp.cumsum(bs, axis=-1)              # tiny: NB entries per row
    target = u.astype(jnp.float32) * cum[:, -1]
    b = jnp.sum((cum <= target[:, None]).astype(jnp.int32), axis=-1)
    b = jnp.minimum(b, nb - 1)
    carry = jnp.where(b > 0,
                      jnp.take_along_axis(cum, jnp.maximum(b - 1, 0)[:, None],
                                          axis=-1)[:, 0], 0.0)
    inner = jnp.take_along_axis(blocks, b[:, None, None], axis=1)[:, 0]
    cs = carry[:, None] + jnp.cumsum(inner, axis=-1)   # (S, BV): one block
    idx = b * bv + jnp.sum((cs <= target[:, None]).astype(jnp.int32),
                           axis=-1)
    return jnp.minimum(idx, v - 1)


def topk_mask_sample_ref(logits, temperature, threshold, u,
                         return_probs: bool = True):
    """Fused warp + sample oracle: per row, temperature/top-k warp the
    logits and draw one token by inverse CDF with uniform ``u`` (greedy rows
    — ``temperature <= 0`` — take the raw argmax and ignore ``u``).

    logits: (S, V); temperature/u: (S,); threshold: (S,) or None (no row
    truncates — skips the masking pass entirely). Returns ``(tokens (S,)
    int32, probs)`` where ``probs`` is the warped (S, V) distribution each
    row actually sampled from (one-hot for greedy rows) — the draft phase
    of speculative decoding keeps it as ``q`` for the accept test — or
    None when ``return_probs`` is unset (the serving hot path: the draw
    samples the unnormalized exponentials directly, skipping the
    normalization and one-hot passes).
    """
    t = jnp.maximum(temperature, 1e-30)[:, None]
    z = logits.astype(jnp.float32) / t
    if threshold is not None:
        z = jnp.where(z >= threshold[:, None], z, -jnp.inf)
    e = jnp.exp(z - jnp.max(z, axis=-1, keepdims=True))
    sampled = sample_cdf_ref(e, u)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tokens = jnp.where(temperature > 0, sampled, greedy)
    if not return_probs:
        return tokens, None
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    one_hot = (greedy[:, None]
               == jnp.arange(logits.shape[-1])[None, :]).astype(jnp.float32)
    return tokens, jnp.where(temperature[:, None] > 0, p, one_hot)


def ssd_ref(x, dt, a, b, c):
    """Sequential SSD recurrence. x: (BH,S,P); dt: (BH,S); a: (BH,); b/c: (BH,S,N)."""
    bh, s, p = x.shape
    n = b.shape[-1]

    def step(state, xs):
        x_t, dt_t, b_t, c_t = xs                          # (BH,P),(BH,),(BH,N)
        decay = jnp.exp(dt_t * a)                         # (BH,)
        state = state * decay[:, None, None] + jnp.einsum(
            "bn,bp->bnp", b_t, x_t * dt_t[:, None])
        y = jnp.einsum("bn,bnp->bp", c_t, state)
        return state, y

    init = jnp.zeros((bh, n, p), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
